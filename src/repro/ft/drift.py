"""Operating-point drift: measurement, detection, degraded resolution.

The optimal TD operating point (R, q, Vdd) depends on the input statistics
the solve assumed -- `p_x_one` (activation bit density) and
`w_bit_sparsity` (PR 3's scenario engine).  When live traffic drifts away
from those statistics the deployed policy is mispriced: either it burns
energy on a worst-case margin the workload no longer needs, or it
undershoots the error budget.  This module is the serving-side feedback
loop:

`measure_p_x_one`
    Cheap running estimator of the activation bit density, pure jnp so it
    fuses into the jitted serve step (maxabs-quantize the embedding
    activations to the policy's bit width, offset-encode, average the bit
    planes -- the exact statistic `cells.input_distribution` prices).
`weight_bit_sparsity`
    One-shot weight-side statistic from the deployed params (weights do
    not drift during serving; measured once at engine build).
`DriftEstimator`
    Host-side EMA + threshold: smooths the per-step measurements and
    flags when the smoothed value leaves a relative band around the
    anchor (the statistic the CURRENT policy was resolved at).  `rearm`
    moves the anchor after a re-resolve so the detector does not re-fire
    on the excursion it just adapted to.
`ResolverChain`
    Graceful degradation for policy resolution: try the primary resolver
    (the explorer TCP client), catch its "unreachable" errors and degrade
    to the fallback (the in-process cached grid) instead of failing the
    request.  Recovers automatically when the primary answers again.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable

import jax.numpy as jnp

from repro.quant import bitserial


def measure_p_x_one(x: jnp.ndarray, bits: int = 4,
                    mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Activation bit density of ``x`` under B-bit maxabs quantization:
    the fraction of ones across all offset-encoded bit planes (a scalar
    f32).  Pure jnp -- jit/fuse freely inside the serve step.

    ``mask`` (optional, broadcastable to ``x.shape[0]``) selects which
    leading-axis rows count: a continuous-batching engine passes its
    occupancy mask so stale activations in recycled-but-free slots do not
    pollute the measured statistic.  An all-zero mask returns 0.5 (the
    uninformative prior) rather than NaN.
    """
    qmax = 2.0 ** (bits - 1) - 1.0
    s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax
    codes = jnp.clip(jnp.round(x / s), -(qmax + 1.0), qmax).astype(jnp.int32)
    planes = bitserial.bit_planes(bitserial.to_offset(codes, bits), bits)
    planes = planes.astype(jnp.float32)      # (bits, *x.shape), LSB first
    if mask is None:
        return jnp.mean(planes)
    m = jnp.reshape(mask.astype(jnp.float32), (-1,) + (1,) * (x.ndim - 1))
    w = jnp.broadcast_to(m, x.shape)
    tot = jnp.float32(bits) * jnp.sum(w)
    return jnp.where(tot > 0,
                     jnp.sum(planes * w[None, ...]) / jnp.maximum(tot, 1.0),
                     jnp.float32(0.5))


def weight_bit_sparsity(w: jnp.ndarray, bits: int = 4) -> float:
    """Fraction of ZERO bits in the B-bit maxabs codes of ``w`` (the
    Section IV 'weight bitwise sparsity' statistic; ~0.70 for ResNet18).
    One-shot host-side measurement -- weights are static during serving."""
    return float(1.0 - measure_p_x_one(jnp.asarray(w), bits))


@dataclasses.dataclass
class DriftEstimator:
    """EMA drift detector over a running operating-point statistic.

    ``anchor`` is the value the current policy was resolved at; `update`
    folds one measurement into the EMA and returns True when the smoothed
    value has left ``(1 +/- threshold) * anchor``.  ``warmup`` raw samples
    must arrive before the detector may fire (a half-seeded EMA would flag
    the very first batch).  After the caller re-resolves, `rearm(new)`
    moves the anchor and re-enters warmup so the detector tracks the NEW
    operating point instead of re-firing on the old excursion.
    """
    anchor: float
    alpha: float = 0.1          # EMA weight of each new sample
    threshold: float = 0.2      # relative band half-width around anchor
    warmup: int = 4
    value: float | None = None  # current EMA (None until first sample)
    samples: int = 0
    excursions: int = 0

    def update(self, measured: float) -> bool:
        m = float(measured)
        self.value = m if self.value is None else \
            (1.0 - self.alpha) * self.value + self.alpha * m
        self.samples += 1
        if self.samples < self.warmup:
            return False
        drifted = abs(self.value - self.anchor) > self.threshold * abs(self.anchor)
        if drifted:
            self.excursions += 1
        return drifted

    def rearm(self, anchor: float) -> None:
        self.anchor = float(anchor)
        self.value = None
        self.samples = 0


class StagedRebuild:
    """A policy rebuild running off-thread, to be installed at a later
    step boundary.

    The supply-spanning re-resolve (Vdd argmin over the scenario grid +
    full per-layer policy solve + meter re-price) is too slow to run
    inside a decode step, so the scheduler stages it: `StagedRebuild`
    runs ``fn`` on a daemon thread and the engine polls at each step
    boundary, installing the result atomically when ready.

    Error contract -- the same as checkpoint `SaveHandle`: an exception
    in the worker thread is captured, not printed-and-lost, and re-raised
    exactly once (wrapped in RuntimeError with the original as __cause__)
    on the next `poll()` / `wait()`.  A resolver failure inside the
    rebuild thread therefore surfaces on the next decode step instead of
    dying silently with the thread.
    """

    def __init__(self, fn: Callable[[], object], name: str = "staged-rebuild"):
        self.result: object | None = None
        self.error: BaseException | None = None
        self._raised = False
        self._thread = threading.Thread(target=self._run, args=(fn,),
                                        name=name, daemon=True)
        self._thread.start()

    def _run(self, fn: Callable[[], object]) -> None:
        try:
            self.result = fn()
        except BaseException as e:       # noqa: BLE001 -- re-raised on poll
            self.error = e

    @property
    def done(self) -> bool:
        return not self._thread.is_alive()

    def _surface(self) -> None:
        if self.error is not None and not self._raised:
            self._raised = True
            raise RuntimeError(
                f"staged rebuild '{self._thread.name}' failed: "
                f"{self.error!r}") from self.error

    def poll(self) -> object | None:
        """Non-blocking: the result if the rebuild finished, else None.
        Raises (once) if the rebuild thread died with an exception."""
        if not self.done:
            return None
        self._surface()
        return self.result

    def wait(self, timeout: float | None = None) -> object | None:
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError("staged rebuild still running")
        self._surface()
        return self.result


class ResolverChain:
    """primary-then-fallback policy resolution.

    ``primary`` and ``fallback`` share a call signature; a primary failure
    of one of the ``catches`` types degrades to the fallback (counted in
    ``fallbacks``, surfaced via ``degraded``) -- anything else propagates.
    A later primary success clears ``degraded``: outage over.
    """

    def __init__(self, primary: Callable, fallback: Callable,
                 catches: tuple[type[BaseException], ...] = (OSError,
                                                            TimeoutError),
                 on_fallback: Callable[[BaseException], None] | None = None):
        self.primary = primary
        self.fallback = fallback
        self.catches = catches
        self.on_fallback = on_fallback
        self.calls = 0
        self.fallbacks = 0
        self.degraded = False

    def __call__(self, *args, **kwargs):
        self.calls += 1
        try:
            out = self.primary(*args, **kwargs)
        except self.catches as e:
            self.fallbacks += 1
            self.degraded = True
            if self.on_fallback is not None:
                self.on_fallback(e)
            return self.fallback(*args, **kwargs)
        self.degraded = False
        return out
