"""Heartbeat/step-time watchdog: tracks a rolling step-time distribution;
a step exceeding p50 * straggler_factor is flagged (at scale: triggers
hot-spare swap or collective reconfiguration; here: logged + counted, and
a standing policy object decides restart vs skip)."""
from __future__ import annotations

import dataclasses
import time
from collections import deque


@dataclasses.dataclass
class WatchdogReport:
    step: int
    duration: float
    p50: float
    is_straggler: bool


class StepWatchdog:
    def __init__(self, straggler_factor: float = 3.0, window: int = 50,
                 warmup_steps: int = 3):
        self.factor = straggler_factor
        self.times: deque = deque(maxlen=window)
        self.warmup = warmup_steps
        self.straggler_count = 0
        self.steps_observed = 0
        self._t0 = None
        self._step = -1

    def start(self, step: int):
        self._step = step
        self._t0 = time.monotonic()

    def stop(self) -> WatchdogReport:
        dur = time.monotonic() - self._t0
        hist = sorted(self.times)
        if hist:
            # true median: average the two middle samples on even windows
            # (hist[len//2] alone is the UPPER middle — biased high)
            mid = len(hist) // 2
            p50 = (hist[mid] if len(hist) % 2
                   else 0.5 * (hist[mid - 1] + hist[mid]))
        else:
            p50 = dur
        # warmup counts every step SEEN, not just the non-straggler samples
        # kept in `times` — otherwise a noisy warmup keeps extending itself
        warm = self.steps_observed >= self.warmup
        self.steps_observed += 1
        straggler = warm and dur > self.factor * p50
        if straggler:
            self.straggler_count += 1
        else:
            self.times.append(dur)   # keep the baseline uncontaminated
        return WatchdogReport(self._step, dur, p50, straggler)
