"""Fault tolerance: the production robustness layer.

The TD stack's paper claim -- energy wins under approximation that
preserves accuracy -- only matters at production scale if the stack
survives the faults production brings.  This package is that layer,
promoted out of the old single-file `launch/ft.py`:

``repro.ft.retry``
    `RetryPolicy` (capped exponential backoff with deterministic seeded
    jitter so synchronized restarts don't stampede), `run_with_retries`,
    the `Preemption` signal and the `RETRYABLE` classification.
``repro.ft.watchdog``
    `StepWatchdog`: rolling step-time p50 with straggler flagging.
``repro.ft.chaos``
    Deterministic chaos engine: a seeded `FaultSchedule` injects, at
    declared steps, preemptions, straggler stalls, checkpoint corruption
    (bit-flip / truncation of ``arrays.npz``), explorer-server outages
    and operating-point drift excursions -- the same schedule replays
    bit-identically for tests and benches (JSON round-trip).
``repro.ft.drift``
    Graceful degradation for serving: cheap running estimators of the
    measured operating point (`measure_p_x_one` inside the jitted serve
    step, `weight_bit_sparsity` once from params), the `DriftEstimator`
    EMA + threshold, and `ResolverChain` (primary resolver with a
    fallback -- e.g. explorer TCP client degrading to the in-process
    cached grid when the server is unreachable).

`launch/ft.py` remains as a thin import shim for old call sites.
"""
from repro.ft.chaos import (CHAOS_KINDS, CORRUPT_MODES, FaultEvent,
                            FaultSchedule, TraceSegment, TrafficTrace,
                            corrupt_checkpoint, excursion_trace)
from repro.ft.drift import (DriftEstimator, ResolverChain, StagedRebuild,
                            measure_p_x_one, weight_bit_sparsity)
from repro.ft.retry import (RETRYABLE, Preemption, RetryPolicy,
                            backoff_delays, run_with_retries)
from repro.ft.watchdog import StepWatchdog, WatchdogReport

__all__ = [
    "CHAOS_KINDS", "CORRUPT_MODES", "FaultEvent", "FaultSchedule",
    "TraceSegment", "TrafficTrace", "corrupt_checkpoint", "excursion_trace",
    "DriftEstimator", "ResolverChain", "StagedRebuild", "measure_p_x_one",
    "weight_bit_sparsity",
    "RETRYABLE", "Preemption", "RetryPolicy", "backoff_delays",
    "run_with_retries",
    "StepWatchdog", "WatchdogReport",
]
