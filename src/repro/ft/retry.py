"""Retry policy: capped, jittered exponential backoff around a resumable
session body.

`run_with_retries` re-enters the body (a full train/serve session that
resumes from the latest checkpoint) on retryable failures -- the loop body
is idempotent by construction (stateless data stream + checkpointed step).
Backoff is exponential with a hard cap (`max_backoff_s`: an uncapped
2^restart ramp quickly turns a flaky dependency into an hours-long stall)
and deterministic seeded jitter (`jitter`, a +/- fraction of the delay):
when a rack-level preemption restarts many workers at once, identical
backoff schedules would stampede the checkpoint store / explorer service
in lockstep, so each process de-synchronizes by its own seed while any
GIVEN seed replays bit-identically for tests.
"""
from __future__ import annotations

import dataclasses
import os
import random
import time
from typing import Callable


class Preemption(RuntimeError):
    """Raised by the environment (or the chaos engine) to simulate node
    loss."""


RETRYABLE = (Preemption, OSError, TimeoutError)


@dataclasses.dataclass
class RetryPolicy:
    """Classification + backoff schedule for `run_with_retries`.

    ``seed=None`` derives the jitter stream from the process id -- the
    de-synchronized production default; pass an explicit seed for a
    bit-reproducible schedule (tests, the chaos bench).
    """
    max_restarts: int = 5
    backoff_s: float = 0.1
    max_backoff_s: float = 30.0
    jitter: float = 0.1          # +/- fraction of the capped delay
    seed: int | None = None

    def delay_s(self, restart: int) -> float:
        """Backoff before the ``restart``-th re-entry (1-based):
        min(base * 2^(restart-1), cap) * (1 + jitter * u), u ~ U[-1, 1)
        drawn deterministically from (seed, restart)."""
        base = min(self.backoff_s * 2.0 ** (restart - 1), self.max_backoff_s)
        if self.jitter <= 0.0:
            return base
        seed = os.getpid() if self.seed is None else self.seed
        u = random.Random(seed * 1_000_003 + restart).uniform(-1.0, 1.0)
        return base * (1.0 + self.jitter * u)


def backoff_delays(policy: RetryPolicy, n: int) -> list[float]:
    """The first ``n`` backoff delays of a policy (bound/spread tests)."""
    return [policy.delay_s(r) for r in range(1, n + 1)]


def run_with_retries(body: Callable[[], object],
                     policy: RetryPolicy | None = None,
                     on_restart: Callable[[int, BaseException], None]
                     | None = None):
    """Run `body` (a full session that resumes from the latest checkpoint)
    restarting on retryable failures.

    `policy=None` constructs a fresh RetryPolicy per call -- a dataclass
    default instance would be one MUTABLE object shared by every call site
    (a caller tweaking `policy.max_restarts` would change everyone else's).
    """
    if policy is None:
        policy = RetryPolicy()
    restarts = 0
    while True:
        try:
            return body()
        except RETRYABLE as e:          # noqa: PERF203
            restarts += 1
            if restarts > policy.max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts, e)
            time.sleep(policy.delay_s(restarts))
