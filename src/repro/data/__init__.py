"""Data pipeline substrate."""
