"""Deterministic synthetic token pipeline.

Stateless-resumable: batch t of the stream is a pure function of
(seed, step, dp_rank), so checkpoint restore needs no data-loader state and
elastic remesh (different dp_rank count) keeps determinism per rank.

The stream is not uniform noise: documents are Zipf-ish token draws with
bos/eos structure and a repeated-ngram backbone so the LM loss actually
decreases during the example runs (pure uniform noise has no learnable
signal).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataCfg:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    bos: int = 1
    eos: int = 2
    zipf_a: float = 1.2
    motif_len: int = 16
    motif_period: int = 64


def _zipf_probs(cfg: DataCfg) -> np.ndarray:
    ranks = np.arange(3, cfg.vocab, dtype=np.float64)
    p = 1.0 / np.power(ranks - 2, cfg.zipf_a)
    probs = np.zeros(cfg.vocab)
    probs[3:] = p / p.sum()
    return probs


class SyntheticStream:
    """Host-side generator; per-rank sharded slices of the global batch."""

    def __init__(self, cfg: DataCfg, dp_rank: int = 0, dp_size: int = 1):
        assert cfg.global_batch % dp_size == 0
        self.cfg = cfg
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.local_batch = cfg.global_batch // dp_size
        self._probs = _zipf_probs(cfg)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.dp_rank]))
        b, s = self.local_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab, size=(b, s + 1), p=self._probs)
        # motif backbone: periodic repeated n-grams (learnable structure)
        motif = rng.choice(cfg.vocab, size=(b, cfg.motif_len),
                           p=self._probs)
        for off in range(0, s + 1 - cfg.motif_len, cfg.motif_period):
            toks[:, off:off + cfg.motif_len] = motif
        # document structure
        doc_len = rng.integers(64, max(65, s // 2))
        toks[:, 0] = cfg.bos
        for pos in range(doc_len, s + 1, doc_len):
            toks[:, pos - 1] = cfg.eos
            if pos < s + 1:
                toks[:, pos] = cfg.bos
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def frontend_batch(self, step: int, n_positions: int,
                       d_frontend: int) -> np.ndarray:
        """Stub modality embeddings (precomputed patch/frame features)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.dp_rank, 7]))
        return rng.standard_normal(
            (self.local_batch, n_positions, d_frontend)).astype(np.float32)
