"""Background-prefetch wrapper around the synthetic stream.

A real deployment replaces SyntheticStream with a memmap shard reader; the
prefetch thread + bounded queue and the stateless step-indexed API stay
identical, which is the property fault-tolerant resume relies on.
"""
from __future__ import annotations

import queue
import threading

from repro.data.synthetic import DataCfg, SyntheticStream


class PrefetchLoader:
    def __init__(self, stream: SyntheticStream, start_step: int = 0,
                 depth: int = 2):
        self.stream = stream
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._next
        while not self._stop.is_set():
            batch = self.stream.batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
